"""Serve subsystem: fake-clock batch cutting, launch-signature grouping,
queue semantics, engine batch assembly, and end-to-end service behaviour.

The service tests run the numpy backend so they stay in the fast tier-1
lane; the device-backend parity test (served report bit-identical to a solo
``solve()``) is ``@pytest.mark.slow`` since it compiles launches.
"""
import asyncio

import numpy as np
import pytest

from repro.core import Budget, TSParams, random_instance, solve
from repro.serve import (
    Batcher,
    BatchPolicy,
    Engine,
    EngineConfig,
    RequestQueue,
    ServiceClosed,
    SolveService,
    WarmSpec,
    launch_signature,
)


class FakeClock:
    """Deterministic queue clock: time moves only via ``advance``."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def small_instance(seed=0, **kw):
    kw.setdefault("n_tasks", 24)
    kw.setdefault("n_data", 60)
    return random_instance(seed, **kw)


INST = small_instance()
BUDGET = Budget(max_iters=4)


# --------------------------------------------------------------------------- #
# launch signatures
# --------------------------------------------------------------------------- #

def test_signature_equal_for_identical_request_shape():
    assert launch_signature(INST, 2, BUDGET) == launch_signature(INST, 2, BUDGET)


def test_signature_splits_on_walks_and_budget():
    base = launch_signature(INST, 2, BUDGET)
    assert launch_signature(INST, 4, BUDGET) != base
    assert launch_signature(INST, 2, Budget(max_iters=99)) != base


def test_signature_splits_on_instance_shape_class():
    big = small_instance(1, n_tasks=80, n_data=200)
    assert launch_signature(big, 2, BUDGET) != launch_signature(INST, 2, BUDGET)


# --------------------------------------------------------------------------- #
# queue
# --------------------------------------------------------------------------- #

def test_queue_fifo_take_and_absolute_deadline():
    clk = FakeClock(10.0)
    q = RequestQueue(clock=clk)
    r0 = q.submit(INST, BUDGET, seed=0, deadline=3.0)
    clk.advance(1.0)
    r1 = q.submit(INST, BUDGET, seed=1)
    assert r0.deadline == pytest.approx(13.0) and r1.deadline is None
    assert r0.submitted == 10.0 and r1.submitted == 11.0
    sig = r0.signature
    assert r1.signature == sig and len(q) == 2
    assert [r.rid for r in q.take(sig, 1)] == [r0.rid]
    assert [r.rid for r in q.take(sig, 5)] == [r1.rid]
    assert len(q) == 0 and q.take(sig, 1) == []


def test_queue_submit_after_close_raises():
    q = RequestQueue(clock=FakeClock())
    q.submit(INST, BUDGET)
    q.close()
    assert q.closed
    with pytest.raises(ServiceClosed):
        q.submit(INST, BUDGET)
    assert len(q) == 1  # pending requests survive close (drain semantics)


# --------------------------------------------------------------------------- #
# batcher: fake-clock cut conditions
# --------------------------------------------------------------------------- #

def make_batcher(clk, **policy_kw):
    q = RequestQueue(clock=clk)
    policy_kw.setdefault("max_batch", 4)
    policy_kw.setdefault("max_wait", 0.5)
    policy_kw.setdefault("deadline_slack", 0.25)
    return q, Batcher(q, BatchPolicy(**policy_kw))


def test_cut_on_full():
    clk = FakeClock()
    q, b = make_batcher(clk, max_batch=3)
    for s in range(3):
        q.submit(INST, BUDGET, seed=s)
    cut = b.cut()
    assert cut is not None and cut.reason == "full" and len(cut) == 3
    assert b.cut() is None and b.cuts_by_reason == {"full": 1}


def test_cut_on_age_after_max_wait():
    clk = FakeClock()
    q, b = make_batcher(clk, max_wait=0.5)
    q.submit(INST, BUDGET, seed=0)
    assert b.cut() is None  # too young, device busy
    clk.advance(0.49)
    assert b.cut() is None
    clk.advance(0.02)
    cut = b.cut()
    assert cut is not None and cut.reason == "age" and len(cut) == 1


def test_cut_on_deadline_within_slack():
    clk = FakeClock()
    # max_wait huge: only the deadline can trigger this cut
    q, b = make_batcher(clk, max_wait=1e9, deadline_slack=0.25)
    q.submit(INST, BUDGET, seed=0, deadline=2.0)
    assert b.cut() is None
    clk.advance(1.74)  # deadline 0.26 away: still outside slack
    assert b.cut() is None
    clk.advance(0.02)  # 0.24 away: inside slack
    cut = b.cut()
    assert cut is not None and cut.reason == "deadline"


def test_cut_when_device_idle_respects_eagerness():
    clk = FakeClock()
    q, b = make_batcher(clk, max_wait=1e9)
    q.submit(INST, BUDGET, seed=0)
    assert b.cut(device_idle=False) is None
    cut = b.cut(device_idle=True)
    assert cut is not None and cut.reason == "idle"

    q2, b2 = make_batcher(clk, max_wait=1e9, eager_when_idle=False)
    q2.submit(INST, BUDGET, seed=0)
    assert b2.cut(device_idle=True) is None


def test_cut_drains_after_close():
    clk = FakeClock()
    q, b = make_batcher(clk, max_wait=1e9)
    q.submit(INST, BUDGET, seed=0)
    q.close()
    cut = b.cut()
    assert cut is not None and cut.reason == "drain"
    assert b.cut() is None and len(q) == 0


def test_cut_never_mixes_signatures_and_serves_oldest_head_first():
    clk = FakeClock()
    q, b = make_batcher(clk, max_batch=8, max_wait=0.1)
    a0 = q.submit(INST, BUDGET, seed=0, walks=2)
    clk.advance(0.01)
    b0 = q.submit(INST, BUDGET, seed=1, walks=4)  # different signature
    clk.advance(0.01)
    a1 = q.submit(INST, BUDGET, seed=2, walks=2)
    clk.advance(0.2)  # both groups age-ready; walks=2 head is oldest
    first = b.cut()
    assert first.reason == "age"
    assert [r.rid for r in first.requests] == [a0.rid, a1.rid]
    assert all(r.signature == first.signature for r in first.requests)
    second = b.cut()
    assert [r.rid for r in second.requests] == [b0.rid]
    assert second.signature != first.signature


def test_next_cut_time_is_min_of_age_and_deadline_horizons():
    clk = FakeClock()
    q, b = make_batcher(clk, max_wait=0.5, deadline_slack=0.25)
    assert b.next_cut_time() is None
    q.submit(INST, BUDGET, seed=0)  # age-ready at t=0.5
    assert b.next_cut_time() == pytest.approx(0.5)
    q.submit(INST, BUDGET, seed=1, deadline=0.6)  # deadline-ready at 0.35
    assert b.next_cut_time() == pytest.approx(0.35)


# --------------------------------------------------------------------------- #
# engine: batch assembly (host-side, no compile)
# --------------------------------------------------------------------------- #

def test_assemble_pads_to_quantized_size_and_pins_bucket_key():
    clk = FakeClock()
    q = RequestQueue(clock=clk)
    b = Batcher(q, BatchPolicy(max_batch=3, max_wait=0.0))
    eng = Engine(EngineConfig(backend="device", batch_sizes=(4,)),
                 params=TSParams())

    for s in range(3):
        q.submit(INST, BUDGET, seed=s)
    asm3 = eng.assemble(b.cut())
    assert asm3.padded_to == 4 and len(asm3.instances) == 4
    # pad lane repeats the last real request
    assert asm3.instances[3] is asm3.instances[2]
    assert asm3.seeds[3] == asm3.seeds[2]
    assert len(asm3.inits) == 4
    np.testing.assert_array_equal(asm3.inits[3][0].assign,
                                  asm3.inits[2][0].assign)

    q.submit(INST, BUDGET, seed=9)
    asm1 = eng.assemble(b.cut(device_idle=True))
    assert asm1.padded_to == 4
    # pinned widths: every cut of one signature lands on one compiled launch
    assert asm1.batch.bucket_key == asm3.batch.bucket_key


def test_assemble_budget_seeds_match_solo_inits():
    from repro.core.api import multiwalk_inits

    clk = FakeClock()
    q = RequestQueue(clock=clk)
    b = Batcher(q, BatchPolicy(max_batch=2, max_wait=0.0))
    eng = Engine(EngineConfig(backend="numpy"), params=TSParams())
    q.submit(INST, BUDGET, seed=7, walks=3)
    asm = eng.assemble(b.cut(device_idle=True))
    assert asm.seeds == [7] and asm.padded_to == 1 and asm.batch is None
    sols, _ = multiwalk_inits(INST, 3, 7)
    assert len(asm.inits[0]) == len(sols)
    for got, want in zip(asm.inits[0], sols):
        np.testing.assert_array_equal(got.assign, want.assign)


# --------------------------------------------------------------------------- #
# service end-to-end (numpy backend: fast, deterministic)
# --------------------------------------------------------------------------- #

NUMPY_CFG = EngineConfig(backend="numpy")
PARAMS = TSParams(top_k=4)


def run(coro):
    return asyncio.run(coro)


def test_service_numpy_parity_and_streaming():
    insts = [small_instance(s) for s in range(3)]

    async def main():
        svc = SolveService(config=NUMPY_CFG,
                           policy=BatchPolicy(max_batch=3, max_wait=0.01),
                           params=PARAMS)
        await svc.start()
        rids = [await svc.submit(inst, BUDGET, seed=10 + k, walks=2)
                for k, inst in enumerate(insts)]
        events = {}
        for rid in rids:
            events[rid] = [ev async for ev in svc.stream_incumbents(rid)]
        results = [await svc.result(rid) for rid in rids]
        metrics = svc.metrics()
        await svc.shutdown()
        return rids, events, results, metrics

    rids, events, results, metrics = run(main())
    total_events = 0
    for k, (rid, rr) in enumerate(zip(rids, results)):
        solo = solve(insts[k], "tabu_multiwalk", walks=2, budget=BUDGET,
                     seed=10 + k, params=PARAMS)
        assert rr.report.makespan == solo.makespan
        assert rr.report.history == solo.history
        np.testing.assert_array_equal(rr.report.solution.assign,
                                      solo.solution.assign)
        assert rr.metrics["rid"] == rid and rr.metrics["latency"] >= 0.0
        for ev in events[rid]:
            assert ev.best_makespan >= rr.report.makespan
        total_events += len(events[rid])
    assert total_events >= 1  # anytime incumbents actually streamed
    assert metrics["completed"] == 3 and metrics["pending"] == 0
    for key in ("submitted", "batches", "mean_batch_size", "cuts_by_reason",
                "latency_p50", "latency_p99"):
        assert key in metrics
    assert not metrics["errors"]


def test_service_shutdown_drains_queue():
    # a policy that never cuts on its own: only the drain path can finish
    never = BatchPolicy(max_batch=10**6, max_wait=1e9, eager_when_idle=False)

    async def main():
        svc = SolveService(config=NUMPY_CFG, policy=never, params=PARAMS)
        await svc.start()
        rids = [await svc.submit(INST, BUDGET, seed=s) for s in range(3)]
        await asyncio.sleep(0.05)  # nothing should have been cut yet
        assert svc.metrics()["completed"] == 0
        await svc.shutdown(drain=True)
        results = [await svc.result(rid) for rid in rids]
        return results, svc.batcher.cuts_by_reason

    results, reasons = run(main())
    assert len(results) == 3
    assert all(rr.report.makespan > 0 for rr in results)
    assert set(reasons) == {"drain"}


def test_service_shutdown_without_drain_fails_pending():
    never = BatchPolicy(max_batch=10**6, max_wait=1e9, eager_when_idle=False)

    async def main():
        svc = SolveService(config=NUMPY_CFG, policy=never, params=PARAMS)
        await svc.start()
        rid = await svc.submit(INST, BUDGET, seed=0)
        await svc.shutdown(drain=False)
        with pytest.raises(ServiceClosed):
            await svc.result(rid)
        with pytest.raises(ServiceClosed):
            await svc.submit(INST, BUDGET, seed=1)
        # dropped request streams still terminate
        return [ev async for ev in svc.stream_incumbents(rid)]

    assert run(main()) == []


def test_service_unknown_rid_raises_keyerror():
    async def main():
        svc = SolveService(config=NUMPY_CFG, params=PARAMS)
        await svc.start()
        with pytest.raises(KeyError):
            await svc.result(999)
        await svc.shutdown()

    run(main())


# --------------------------------------------------------------------------- #
# device-backend parity (compiles a launch: slow lane)
# --------------------------------------------------------------------------- #

@pytest.mark.slow
def test_service_device_parity_with_solo_solve():
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core.device_search import MEM_UPDATE_DISABLED

    inst = random_instance(0, n_tasks=40, n_data=100)
    budget = Budget(max_iters=6)
    params = TSParams(max_unimproved=10**9, time_limit=1e9, top_k=5,
                      mem_update_period=MEM_UPDATE_DISABLED)
    cfg = EngineConfig(backend="device", sync_every=8, crit_cap=32,
                       batch_sizes=(2,))

    async def main():
        svc = SolveService(config=cfg,
                           policy=BatchPolicy(max_batch=2, max_wait=0.01),
                           params=params,
                           warm=[WarmSpec(inst, 2, budget)])
        await svc.start()
        rids = [await svc.submit(inst, budget, seed=s, walks=2)
                for s in (3, 9)]
        results = [await svc.result(rid) for rid in rids]
        metrics = svc.metrics()
        await svc.shutdown()
        return results, metrics

    results, metrics = run(main())
    assert metrics["warmup"]["signatures"] == 1
    for seed, rr in zip((3, 9), results):
        solo = solve(inst, "tabu_device", walks=2, budget=budget, seed=seed,
                     params=params,
                     device={"sync_every": 8, "crit_cap": 32})
        assert rr.report.makespan == solo.makespan
        assert rr.report.history == solo.history
        np.testing.assert_array_equal(rr.report.solution.assign,
                                      solo.solution.assign)
        np.testing.assert_array_equal(rr.report.solution.mem,
                                      solo.solution.mem)
