"""Hypothesis property tests for the serve batcher.

Invariants under arbitrary arrival orders, shape classes, and clock steps:
a cut batch never mixes launch-shape signatures, respects ``max_batch``,
preserves per-signature FIFO order, and draining loses or duplicates no
request.  Separate file so tier-1 still collects without ``hypothesis``
(optional dev dependency, present in CI).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Budget, random_instance  # noqa: E402
from repro.serve import Batcher, BatchPolicy, RequestQueue  # noqa: E402

from test_serve import FakeClock  # noqa: E402

# a handful of distinct launch-shape classes (shape class x walks x budget)
_INSTANCES = [random_instance(s, n_tasks=n, n_data=2 * n)
              for s, n in ((0, 16), (1, 16), (2, 48))]
_BUDGETS = [Budget(max_iters=2), Budget(max_iters=5)]

arrival = st.tuples(st.integers(0, len(_INSTANCES) - 1),
                    st.sampled_from([1, 2, 4]),
                    st.integers(0, len(_BUDGETS) - 1),
                    st.floats(0.0, 0.2))


@settings(max_examples=25, deadline=None)
@given(arrivals=st.lists(arrival, min_size=1, max_size=24),
       max_batch=st.integers(1, 6),
       max_wait=st.floats(0.0, 0.5))
def test_cuts_partition_requests_without_mixing_signatures(
        arrivals, max_batch, max_wait):
    clk = FakeClock()
    queue = RequestQueue(clock=clk)
    batcher = Batcher(queue, BatchPolicy(max_batch=max_batch,
                                         max_wait=max_wait,
                                         deadline_slack=0.25))
    submitted = []
    cuts = []
    for inst_i, walks, budget_i, dt in arrivals:
        clk.advance(dt)
        submitted.append(queue.submit(_INSTANCES[inst_i],
                                      _BUDGETS[budget_i], walks=walks,
                                      seed=len(submitted)))
        cut = batcher.cut()  # interleave cutting with arrivals
        if cut is not None:
            cuts.append(cut)
    queue.close()  # drain whatever is left
    while True:
        cut = batcher.cut()
        if cut is None:
            break
        cuts.append(cut)

    assert len(queue) == 0
    for cut in cuts:
        # never mixes launch-shape classes, never exceeds max_batch
        assert len(cut) <= max_batch
        assert all(r.signature == cut.signature for r in cut.requests)
        # per-signature FIFO: rids within a cut are increasing
        rids = [r.rid for r in cut.requests]
        assert rids == sorted(rids)
    # no request lost, none duplicated
    served = [r.rid for cut in cuts for r in cut.requests]
    assert sorted(served) == [r.rid for r in submitted]
