"""Array-native tabu search: packed search state, vectorized neighborhoods,
the batched approximate-evaluation kernel, the vectorized Algorithm 3, and
the multi-walk driver's W=1 trajectory parity with the legacy scalar loop."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    InfeasibleInstanceError,
    TSParams,
    list_solvers,
    random_instance,
    solve,
)
from repro.core.eval_batch import MoveBatch, PackedSolutions, approx_eval_moves, batch_evaluate
from repro.core.greedy import STRATEGIES, construct_greedy
from repro.core.memory_update import memory_update
from repro.core.solution import Solution, exact_schedule, heads_tails
from repro.core.tabu import (
    Move,
    _approx_eval,
    _cc_moves,
    _n7_moves,
    _perturb,
    apply_move,
    tabu_multiwalk,
    tabu_search,
)


def small_instance(seed=0, **kw):
    kw.setdefault("n_tasks", 40)
    kw.setdefault("n_data", 100)
    return random_instance(seed, **kw)


def incumbent_with_neighborhood(seed, n_tasks=50, n_data=120):
    inst = random_instance(seed, n_tasks=n_tasks, n_data=n_data)
    sol = memory_update(inst, construct_greedy(inst, STRATEGIES[seed % 4], rng=seed))
    sched = exact_schedule(inst, sol)
    r, q, _, crit = heads_tails(inst, sol, sched)
    moves = _n7_moves(sol, crit) + _cc_moves(inst, sol, crit, r, sched.start, 5)
    return inst, sol, sched, (r, q, crit), moves


def to_batch(moves) -> MoveBatch:
    return MoveBatch(
        cc=np.array([m.kind == "cc" for m in moves], dtype=bool),
        task=np.array([m.task for m in moves], dtype=np.int64),
        src_proc=np.array([m.src_proc for m in moves], dtype=np.int64),
        src_pos=np.array([m.src_pos for m in moves], dtype=np.int64),
        dst_proc=np.array([m.dst_proc for m in moves], dtype=np.int64),
        dst_pos=np.array([m.dst_pos for m in moves], dtype=np.int64),
    )


# --------------------------------------------------------------------------- #
# packed search state                                                          #
# --------------------------------------------------------------------------- #
def test_packed_state_roundtrip_and_positions():
    inst, sol, *_ = incumbent_with_neighborhood(0)
    packed = PackedSolutions.from_solutions(inst, [sol])
    back = packed.to_solution(0)
    assert np.array_equal(back.assign, sol.assign)
    assert np.array_equal(back.mem, sol.mem)
    assert back.proc_seq == sol.proc_seq
    mach, pos = packed.positions()
    m_ref, p_ref = sol.positions(inst.n_tasks)
    assert np.array_equal(mach[0], m_ref)
    assert np.array_equal(pos[0], p_ref)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_apply_moves_matches_scalar_apply_move(seed):
    """Gather/scatter candidate generation == list-surgery apply_move."""
    inst, sol, _, _, moves = incumbent_with_neighborhood(seed)
    assert moves
    packed = PackedSolutions.from_solutions(inst, [sol])
    mb = to_batch(moves)
    cands = packed.apply_moves(np.zeros(len(moves), dtype=np.int64), mb)
    for i, m in enumerate(moves):
        ref = sol.copy()
        apply_move(ref, m)
        mp, ms = ref.machine_pred_succ(inst.n_tasks)
        assert np.array_equal(cands.assign[i], ref.assign), (i, m)
        assert np.array_equal(cands.mpred[i], mp), (i, m)
        assert np.array_equal(cands.msucc[i], ms), (i, m)


def test_commit_move_keeps_state_in_sync():
    inst, sol, _, _, moves = incumbent_with_neighborhood(3)
    packed = PackedSolutions.from_solutions(inst, [sol])
    ref = sol.copy()
    applied = 0
    for m in moves:
        mach, pos = ref.positions(inst.n_tasks)
        if mach[m.task] != m.src_proc or pos[m.task] != m.src_pos:
            continue  # stale after earlier commits; skip
        limit = len(ref.proc_seq[m.dst_proc]) - (m.kind == "n7")
        if m.dst_pos > limit:
            continue  # insertion index stale too
        apply_move(ref, m)
        packed.commit_move(0, m)
        applied += 1
        if applied >= 5:
            break
    assert applied >= 2
    back = packed.to_solution(0)
    assert back.proc_seq == ref.proc_seq
    mp, ms = ref.machine_pred_succ(inst.n_tasks)
    assert np.array_equal(packed.mpred[0], mp)
    assert np.array_equal(packed.msucc[0], ms)
    assert np.array_equal(packed.assign[0], ref.assign)


# --------------------------------------------------------------------------- #
# batched approximate evaluation                                               #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_batched_approx_bit_exact_with_scalar(seed):
    """The (M,) kernel must be array_equal with the per-move oracle."""
    inst, sol, sched, (r, q, crit), moves = incumbent_with_neighborhood(seed)
    assert len(moves) > 100  # a meaningful neighborhood
    dur = sched.finish - sched.start
    packed = PackedSolutions.from_solutions(inst, [sol])
    est_batch = approx_eval_moves(inst, packed, 0, to_batch(moves), r, q, dur)
    est_scalar = np.array(
        [_approx_eval(inst, sol, m, r, q, dur) for m in moves])
    assert np.array_equal(est_batch, est_scalar)


def test_approx_ranking_quality_spearman():
    """The approximate estimate must rank neighborhoods usefully (the mixed
    strategy's premise): Spearman(approx, exact) >= 0.5 on sampled moves."""
    rhos = []
    for seed in range(3):
        inst, sol, sched, (r, q, crit), moves = incumbent_with_neighborhood(seed)
        dur = sched.finish - sched.start
        packed = PackedSolutions.from_solutions(inst, [sol])
        est = approx_eval_moves(inst, packed, 0, to_batch(moves), r, q, dur)
        cands = []
        kept_est = []
        for m, e in zip(moves, est):
            if not np.isfinite(e):
                continue
            c = sol.copy()
            apply_move(c, m)
            cands.append(c)
            kept_est.append(e)
        ev = batch_evaluate(inst, cands)
        feas = ev.feasible
        a = np.asarray(kept_est)[feas]
        b = ev.makespan[feas]
        assert len(a) > 50
        ra = np.argsort(np.argsort(a))
        rb = np.argsort(np.argsort(b))
        rhos.append(float(np.corrcoef(ra, rb)[0, 1]))
    assert min(rhos) >= 0.5, rhos


# --------------------------------------------------------------------------- #
# vectorized Algorithm 3                                                       #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("refresh_every", [1, 8])
def test_memory_update_fast_matches_scalar_oracle(seed, refresh_every):
    inst = small_instance(seed, fast_mem_fraction=0.12)
    sol = construct_greedy(inst, "slack_first", rng=seed)
    fast = memory_update(inst, sol, refresh_every=refresh_every)
    ref = memory_update(inst, sol, refresh_every=refresh_every, scalar=True)
    assert np.array_equal(fast.mem, ref.mem)


def test_tabu_trajectory_identical_across_mem_update_paths():
    """Alg-3 fast path is allocation-identical, so the whole search retraces."""
    inst = small_instance(5)
    base = TSParams(max_unimproved=12, time_limit=60.0, top_k=4, max_iters=40, seed=1)
    a = tabu_search(inst, construct_greedy(inst, "slack_first", rng=1), base)
    b = tabu_search(inst, construct_greedy(inst, "slack_first", rng=1),
                    dataclasses.replace(base, mem_update_scalar=True))
    assert a.history == b.history
    assert a.n_exact_evals == b.n_exact_evals
    assert a.best_makespan == b.best_makespan


# --------------------------------------------------------------------------- #
# multi-walk driver                                                            #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["numpy", "scalar"])
@pytest.mark.parametrize("seed", [0, 4])
def test_w1_reproduces_legacy_trajectory(backend, seed):
    """The acceptance contract: W=1 == legacy tabu_search, bit for bit."""
    inst = small_instance(seed)
    params = TSParams(max_unimproved=15, time_limit=60.0, top_k=5,
                      max_iters=60, seed=3, backend=backend)
    legacy = tabu_search(inst, construct_greedy(inst, "slack_first", rng=3), params)
    mw = tabu_multiwalk(inst, [construct_greedy(inst, "slack_first", rng=3)], params)
    assert mw.history == legacy.history
    assert mw.best_makespan == legacy.best_makespan
    assert mw.iterations == legacy.iterations
    assert mw.n_exact_evals == legacy.n_exact_evals
    assert mw.n_approx_evals == legacy.n_approx_evals
    assert mw.stop_reason == legacy.stop_reason
    assert np.array_equal(mw.best.assign, legacy.best.assign)
    assert np.array_equal(mw.best.mem, legacy.best.mem)
    assert mw.best.proc_seq == legacy.best.proc_seq


def test_w1_solver_matches_tabu_solver_through_solve():
    inst = small_instance(6)
    params = TSParams(max_unimproved=12, time_limit=60.0, top_k=4, max_iters=40)
    a = solve(inst, "tabu", params=params, seed=2)
    b = solve(inst, "tabu_multiwalk", walks=1, params=params, seed=2)
    assert b.history == a.history
    assert b.makespan == a.makespan
    assert b.n_exact_evals == a.n_exact_evals


def test_multiwalk_registered_and_report_well_formed():
    assert "tabu_multiwalk" in list_solvers()
    inst = small_instance(7)
    rep = solve(inst, "tabu_multiwalk", walks=4,
                params=TSParams(max_unimproved=10, time_limit=30.0, top_k=4,
                                max_iters=30), seed=0)
    assert rep.method == "tabu_multiwalk"
    assert rep.feasible
    assert rep.extras["walks"] == 4
    per_walk = rep.extras["per_walk"]
    assert len(per_walk) == 4
    # the driver's incumbent is the best across walks, and each walk never
    # worsens its own init
    assert rep.makespan == min(w["best_makespan"] for w in per_walk)
    for w in per_walk:
        assert w["best_makespan"] <= w["initial_makespan"] + 1e-9
        sched = exact_schedule(inst, w["solution"])
        assert sched is not None
        assert np.isclose(sched.makespan, w["best_makespan"], rtol=1e-9)
    sched = exact_schedule(inst, rep.solution)
    assert np.isclose(sched.makespan, rep.makespan, rtol=1e-9)


def test_more_walks_never_worse_under_shared_nonbinding_budget():
    """Walk 0 of a W-walk run retraces the single walk when the shared budget
    does not bind, so best-of-W <= single-walk."""
    inst = small_instance(8)
    params = TSParams(max_unimproved=10, time_limit=60.0, top_k=4, max_iters=40)
    single = solve(inst, "tabu_multiwalk", walks=1, params=params, seed=1)
    multi = solve(inst, "tabu_multiwalk", walks=4, params=params, seed=1)
    assert multi.makespan <= single.makespan + 1e-9
    assert multi.extras["per_walk"][0]["best_makespan"] == single.makespan


def test_multiwalk_respects_eval_budget():
    from repro.core import Budget

    inst = small_instance(9)
    rep = solve(inst, "tabu_multiwalk", walks=3, budget=Budget(max_evals=40),
                params=TSParams(max_unimproved=10**9, time_limit=60.0))
    # chunk sizes are clamped to the cap; overshoot is bounded by the
    # per-walk post-accept re-evaluation / perturbation evals of one round
    slack = 3 * (TSParams().perturbation_size + 1)
    assert rep.n_exact_evals <= 40 + slack
    assert rep.stop_reason == "max_evals"


def test_multiwalk_callbacks_fire_once_per_iteration():
    from repro.core import Callbacks

    inst = small_instance(10)
    seen = []
    cb = Callbacks(on_iteration=lambda ev: seen.append(ev) or len(seen) >= 5)
    rep = solve(inst, "tabu_multiwalk", walks=3, callbacks=cb,
                params=TSParams(max_unimproved=10**9, time_limit=60.0))
    assert rep.stop_reason == "callback"
    assert len(seen) == 5
    assert [ev.iteration for ev in seen] == [1, 2, 3, 4, 5]


# --------------------------------------------------------------------------- #
# perturbation (Alg. 2 line 11) regression                                     #
# --------------------------------------------------------------------------- #
def _assert_valid_solution(inst, sol):
    all_tasks = sorted(t for seq in sol.proc_seq for t in seq)
    assert all_tasks == list(range(inst.n_tasks))
    for p, seq in enumerate(sol.proc_seq):
        for t in seq:
            assert sol.assign[t] == p


def test_perturbation_hammered_with_seeded_rngs():
    """The perturbation path must keep solutions well-formed under heavy use
    (regression for the dst_pos construction bug)."""
    inst = small_instance(11)
    params = TSParams()
    sol = memory_update(inst, construct_greedy(inst, "slack_first", rng=0))
    sched = exact_schedule(inst, sol)
    _, _, _, crit = heads_tails(inst, sol, sched)
    for seed in range(25):
        rng = np.random.default_rng(seed)
        cur, cur_sched = sol.copy(), sched
        for _ in range(4):
            cur, cur_sched, n_evals = _perturb(inst, cur, cur_sched, crit, rng, params)
            assert 0 <= n_evals <= params.perturbation_size
            _assert_valid_solution(inst, cur)
            s = exact_schedule(inst, cur)
            assert s is not None  # _perturb only keeps schedulable candidates
            assert s.makespan == cur_sched.makespan


class _EndInsertRng:
    """Deterministic rng double: always pick task u, core b, and the highest
    insertion index the perturbation allows."""

    def __init__(self, u, b):
        self.u, self.b = u, b
        self.upper = None

    def choice(self, arr):
        arr = np.asarray(arr)
        want = self.u if self.upper is None else self.b
        self.upper = -1  # next choice() call selects the core
        return want if want in arr else int(arr[0])

    def integers(self, lo, hi):
        self.hi_seen = hi
        self.upper = None  # reset for the next perturbation step
        return hi - 1


def test_perturbation_change_core_can_insert_at_end():
    """The fixed dst_pos range must reach the end of the target sequence for
    change-core moves (the old `or`-bound expression could not)."""
    inst = small_instance(12)
    sol = memory_update(inst, construct_greedy(inst, "slack_first", rng=0))
    sched = exact_schedule(inst, sol)
    crit = np.ones(inst.n_tasks, dtype=bool)
    mach, _ = sol.positions(inst.n_tasks)
    # pick a task with at least one other compatible core
    u = b = None
    for t in range(inst.n_tasks):
        procs = [int(p) for p in inst.compatible_procs(t) if p != mach[t]]
        if procs and len(sol.proc_seq[procs[0]]) >= 2:
            u, b = t, procs[0]
            break
    assert u is not None
    target_len = len(sol.proc_seq[b])
    rng = _EndInsertRng(u, b)
    params = TSParams(perturbation_size=1)
    cur, _, _ = _perturb(inst, sol, sched, crit, rng, params)
    assert rng.hi_seen == target_len + 1  # [0, len] inclusive for change-core
    if cur is not sol:  # candidate kept (acyclic): u is now last on core b
        assert cur.proc_seq[b][-1] == u


# --------------------------------------------------------------------------- #
# greedy infeasibility diagnostics                                             #
# --------------------------------------------------------------------------- #
def _tiny_instance(data_size):
    from repro.core.mdfg import Instance

    # one task consuming initial-input d0 and producing d1; a single FINITE
    # tier (deliberately unvalidatable: validate_instance demands an
    # unbounded fallback, which is exactly what these diagnostics replace)
    return Instance(
        n_tasks=1,
        n_data=2,
        task_edges=np.zeros((0, 2), np.int64),
        producer=np.array([-1, 0]),
        cons_indptr=np.array([0, 1, 1]),
        cons_idx=np.array([0]),
        in_indptr=np.array([0, 1]),
        in_idx=np.array([0]),
        out_indptr=np.array([0, 1]),
        out_idx=np.array([1]),
        proc_time=np.array([[2.0]]),
        data_size=np.asarray(data_size, dtype=np.float64),
        mem_cap=np.array([5.0]),
        access_time=np.array([[0.1]]),
        mem_level=np.array([0]),
        data_mem_ok=np.ones((2, 1), bool),
    )


def test_greedy_raises_on_unplaceable_initial_input():
    inst = _tiny_instance([10.0, 1.0])  # d0 (initial) cannot fit anywhere
    with pytest.raises(InfeasibleInstanceError, match="initial-input block 0") as ei:
        construct_greedy(inst, "slack_first")
    assert ei.value.block == 0
    assert ei.value.task == -1
    assert ei.value.tiers_tried == (0,)


def test_greedy_raises_on_unplaceable_output_block():
    inst = _tiny_instance([1.0, 10.0])  # d1 (output of task 0) cannot fit
    with pytest.raises(InfeasibleInstanceError, match="block 1") as ei:
        construct_greedy(inst, "slack_first")
    assert ei.value.block == 1
    assert ei.value.task == 0
    assert ei.value.tiers_tried == (0,)
